"""Partial-consensus gossip: multi-device semantics via subprocess (device
count must be set before jax init; the main pytest process keeps 1 device)."""
import json

import pytest

GOSSIP_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib, fedavg
from repro.core.reputation import IMPL2
from repro.launch.mesh import make_fed_mesh

F, D = 4, 8
mesh = make_fed_mesh(F, 1, 1)
models = jnp.arange(F * D, dtype=jnp.float32).reshape(F, D)
rep = jnp.ones((F, F))
# eval returns a deterministic per-node accuracy from the model itself
def eval_fn(params, vb):
    return jnp.clip(jnp.mean(params) / 40.0, 0.0, 1.0)
round_fn = gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL2, mesh=mesh)
vb = jnp.zeros((F, 1))
with mesh:
    new, new_rep, m = jax.jit(round_fn)(models, rep, vb)

# host-side oracle: each node averages its ring neighbors weighted by
# rep * acc (receiver-measured), Eq. 3 with its own model as prev
def acc_of(i): return float(np.clip(np.mean(np.arange(i*D,(i+1)*D))/40.0, 0, 1))
expect = np.zeros((F, D))
for i in range(F):
    nb = [(i - 1) % F, (i + 1) % F]
    w = np.array([1.0 * acc_of(j) for j in nb])
    stack = np.stack([np.arange(j*D,(j+1)*D, dtype=np.float32) for j in nb])
    avg = (w / w.sum()) @ stack
    expect[i] = 0.5 * (avg + np.arange(i*D,(i+1)*D))
np.testing.assert_allclose(np.asarray(new), expect, rtol=1e-5)

# reputation: each node punished its lowest-accuracy neighbor by 0.05
rep_np = np.asarray(new_rep)
for i in range(F):
    worst = min([(i-1)%F, (i+1)%F], key=acc_of)
    assert abs(rep_np[i, worst] - 0.95) < 1e-6, (i, rep_np[i])
print(json.dumps({"ok": True}))
"""

LOCAL_ISOLATION = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib
from repro.launch.mesh import make_fed_mesh

F = 4
mesh = make_fed_mesh(F, 1, 1)
def train_step(state, batch):
    # 'training' = add my batch mean; leaks across nodes would show up
    return {"w": state["w"] + jnp.mean(batch)}, {"loss": jnp.mean(batch)}
local = gossip_lib.make_local_steps(train_step, fed_axis="fed", mesh=mesh)
state = {"w": jnp.zeros((F, 2))}
batches = jnp.arange(F * 3 * 2, dtype=jnp.float32).reshape(F, 3, 2)
with mesh:
    out, metrics = jax.jit(local)(state, batches)
expect = np.asarray([batches[i].reshape(3, -1).mean(1).sum() for i in range(F)])
np.testing.assert_allclose(np.asarray(out["w"])[:, 0], expect, rtol=1e-6)
print(json.dumps({"ok": True}))
"""

INT8_GOSSIP = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import gossip as gossip_lib
from repro.core.reputation import IMPL1
from repro.launch.mesh import make_fed_mesh

F, D = 4, 512
mesh = make_fed_mesh(F, 1, 1)
key = jax.random.PRNGKey(0)
models = jax.random.normal(key, (F, D))
rep = jnp.ones((F, F))
eval_fn = lambda p, vb: jnp.asarray(0.5)
mk = lambda comp: gossip_lib.make_gossip_round(
    eval_fn, fed_axis="fed", fed_size=F, ttl=1, rep_impl=IMPL1,
    compress=comp, mesh=mesh)
vb = jnp.zeros((F, 1))
with mesh:
    exact, _, _ = jax.jit(mk(None))(models, rep, vb)
    quant, _, _ = jax.jit(mk("int8"))(models, rep, vb)
rel = float(jnp.max(jnp.abs(exact - quant)) / jnp.max(jnp.abs(exact)))
assert rel < 0.02, rel
print(json.dumps({"ok": True, "rel": rel}))
"""


@pytest.mark.parametrize("name,code", [
    ("gossip_matches_oracle", GOSSIP_EQUIV),
    ("local_steps_isolated_per_node", LOCAL_ISOLATION),
    ("int8_compressed_gossip_close_to_exact", INT8_GOSSIP),
])
def test_multidevice(subprocess_runner, name, code):
    res = subprocess_runner(code, host_devices=4)
    assert res.returncode == 0, res.stderr[-3000:]
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]
