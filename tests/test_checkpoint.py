"""Checkpoint/restart: roundtrip, digest-chain audit, corruption detection."""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.train import checkpoint as ck
from repro.train import step as step_lib


@pytest.fixture
def state():
    cfg = smoke_config("xlstm-125m")
    st, _ = step_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    return st


def test_roundtrip(tmp_path, state):
    ck.save(str(tmp_path), state, 10, arch="xlstm-125m")
    restored, step = ck.restore(str(tmp_path), state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chain_links_and_latest(tmp_path, state):
    d1 = ck.save(str(tmp_path), state, 10)
    d2 = ck.save(str(tmp_path), state, 20)
    assert ck.verify_chain(str(tmp_path))
    m = ck.latest_manifest(str(tmp_path))
    assert m["step"] == 20 and m["prev_digest"] == d1 and m["digest"] == d2
    _, step = ck.restore(str(tmp_path), state)
    assert step == 20


def test_corruption_detected(tmp_path, state):
    ck.save(str(tmp_path), state, 5)
    # flip bytes in the shard
    shard = os.path.join(str(tmp_path), "step_00000005", "shard-0.npz")
    data = dict(np.load(shard))
    k = sorted(data)[0]
    data[k] = data[k] + 1.0
    np.savez(shard, **data)
    with pytest.raises(ValueError, match="corruption"):
        ck.restore(str(tmp_path), state)


def test_manifest_tamper_detected(tmp_path, state):
    ck.save(str(tmp_path), state, 5)
    mf = os.path.join(str(tmp_path), "step_00000005", "manifest.json")
    m = json.load(open(mf))
    m["step"] = 6
    json.dump(m, open(mf, "w"))
    assert not ck.verify_chain(str(tmp_path))


def test_prune_keeps_latest(tmp_path, state):
    for s in (1, 2, 3, 4):
        ck.save(str(tmp_path), state, s)
    ck.prune(str(tmp_path), keep=2)
    steps = [m["step"] for _, m in ck._manifests(str(tmp_path))]
    assert steps == [3, 4]
