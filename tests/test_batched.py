"""Batched federation runs (``BatchedFederationSpec`` -> one vmapped scan)
vs independent single runs: the contract is BITWISE equality, member by
member, across heterogeneous attacker sheets, dead sets, stragglers,
countdowns and per-federation seeds — on every delivery engine. Plus the
max-over-batch budget semantics and the batched overflow fail-fast naming
the offending federation index."""
import numpy as np
import pytest

from repro.chain import scenarios, simlax
from repro.chain.attacks import BatchedFederationSpec, FederationSpec
from repro.core import topology as T
from repro.core.reputation import IMPL2


def _hetero_specs(n):
    """Eight federations, no two alike: mixed attacks, a dead node, a
    straggler, an explicit countdown, and honest baselines."""
    return [
        FederationSpec.build(n, malicious=(0,), attack="gaussian"),
        FederationSpec.build(n, malicious={2: "signflip", 5: "gaussian"},
                             stragglers={7: 2}),
        FederationSpec.build(n, malicious=(1, 3), attack="scaled",
                             dead=(n - 1,)),
        FederationSpec.build(n),
        FederationSpec.build(n, malicious=(4,), attack="freerider"),
        FederationSpec.build(n, malicious=(0, 2), attack="intermittent",
                             initial_countdown=[1 + (3 * i) % 7
                                                for i in range(n)]),
        FederationSpec.build(n, dead=(2, 5)),
        FederationSpec.build(n, malicious=(6,), attack="signflip",
                             stragglers={1: 3}),
    ]


def _cfg(ticks, seed=0, delivery="compact", interval=(8, 12)):
    return simlax.SimLaxConfig(ticks=ticks, train_interval=interval,
                               latency=2, ttl=2, record_every=10,
                               seed=seed, delivery=delivery)


def _assert_result_equal(batched, single, b, engine):
    import jax

    ctx = f"federation {b}, engine {engine}"
    for a, c in zip(jax.tree.leaves(batched.params),
                    jax.tree.leaves(single.params)):
        assert np.array_equal(a, c), f"params diverged: {ctx}"
    assert np.array_equal(batched.reputation, single.reputation), ctx
    assert np.array_equal(batched.acc_history, single.acc_history), ctx
    assert np.array_equal(batched.record_ticks, single.record_ticks), ctx
    for a, c in zip(jax.tree.leaves(batched.sent),
                    jax.tree.leaves(single.sent)):
        assert np.array_equal(a, c), f"sent diverged: {ctx}"
    for k in ("broadcasts", "deliveries", "fedavg_rounds"):
        assert batched.stats[k] == single.stats[k], f"{k}: {ctx}"
    for k in ("arrive", "w_sum", "buf_cnt", "next_train"):
        assert np.array_equal(batched.final_state[k],
                              single.final_state[k]), f"{k}: {ctx}"


@pytest.mark.parametrize(
    "engine", [e for e in simlax.DELIVERY_ENGINES if e != "sharded"])
def test_batched_eight_matches_singles_bitwise(engine):
    # "sharded" is excluded by contract: BatchedFederationSpec does not
    # compose with the shard_map engine (see docs/SCALING.md); the engine
    # raises on the combination, covered in tests/test_sharded.py.
    """The acceptance pin: one batched run() over 8 heterogeneous specs ==
    8 independent single runs, bit for bit, on every delivery engine."""
    n, ticks = 16, 48
    topo = T.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=8)
    specs = _hetero_specs(n)
    seeds = [3 * b + 1 for b in range(len(specs))]
    bsim = simlax.LaxSimulator(sc, topo,
                               BatchedFederationSpec.build(specs, seeds),
                               IMPL2, _cfg(ticks, delivery=engine))
    results = bsim.run()
    assert len(results) == len(specs)
    for b, (spec, seed, bres) in enumerate(zip(specs, seeds, results)):
        single = simlax.LaxSimulator(
            sc, topo, spec, IMPL2, _cfg(ticks, seed=seed, delivery=engine)
        ).run()
        _assert_result_equal(bres, single, b, engine)
        assert bres.stats["federation_index"] == b
        assert bres.stats["batch_size"] == len(specs)
        assert bres.stats["seed"] == seed


def test_batched_seeds_actually_differ():
    """Same spec at different seeds must NOT produce identical members —
    guards against the seed axis being silently dropped."""
    n, ticks = 12, 40
    topo = T.ring(n)
    sc = scenarios.toy_scenario(n, dim=8)
    spec = FederationSpec.build(n, malicious=(0,))
    res = simlax.LaxSimulator(
        sc, topo, BatchedFederationSpec.build([spec, spec], [0, 99]),
        IMPL2, _cfg(ticks)).run()
    import jax
    leaves0, leaves1 = (jax.tree.leaves(res[0].params),
                        jax.tree.leaves(res[1].params))
    assert any(not np.array_equal(a, c) for a, c in zip(leaves0, leaves1))


def test_batched_spec_validation():
    a, b = FederationSpec.build(8), FederationSpec.build(9)
    with pytest.raises(ValueError, match="num_nodes"):
        BatchedFederationSpec.build([a, b])
    with pytest.raises(ValueError, match="seeds"):
        BatchedFederationSpec.build([a, a], seeds=[1])
    with pytest.raises(ValueError):
        BatchedFederationSpec.build([])


def test_batched_spec_size_mismatch_names_member():
    """Mixed-size members are rejected at spec build (with the member
    index); a consistent batch against the wrong topology is rejected at
    simulator build."""
    with pytest.raises(ValueError, match="member 1"):
        BatchedFederationSpec.build(
            [FederationSpec.build(8), FederationSpec.build(12)])
    topo = T.ring(8)
    sc = scenarios.toy_scenario(8, dim=4)
    bspec = BatchedFederationSpec.build(
        [FederationSpec.build(12), FederationSpec.build(12)])
    with pytest.raises(ValueError, match="batch member 0"):
        simlax.LaxSimulator(sc, topo, bspec, IMPL2, _cfg(10))


def test_batch_budgets_take_max_over_members():
    """Shared engine budgets are the max over per-member budgets computed
    on each member's own dead-masked adjacency."""
    n, ttl, interval = 12, 2, (8, 12)
    topo = T.kregular(n, 2)
    # member 1 kills node 0's neighbors -> smaller balls around the hole
    dead_sets = [(), (1, n - 1)]
    bb = T.batch_budgets(topo.adj, ttl, interval, dead_sets)
    assert bb.delivery == max(bb.per_federation_delivery)
    assert bb.compaction == max(bb.per_federation_compaction)
    assert len(bb.per_federation_delivery) == 2
    # the no-dead member's budgets match the single-federation functions
    assert bb.per_federation_delivery[0] == T.delivery_budget(topo.adj, ttl)
    assert bb.per_federation_compaction[0] == \
        T.compaction_budget(topo.adj, ttl, interval)
    # killing nodes never grows a ball
    assert bb.per_federation_delivery[1] <= bb.per_federation_delivery[0]
    # the simulator exposes the shared (max) budgets
    sc = scenarios.toy_scenario(n, dim=4)
    bspec = BatchedFederationSpec.build(
        [FederationSpec.build(n, dead=d) for d in dead_sets])
    sim = simlax.LaxSimulator(
        sc, topo, bspec, IMPL2,
        simlax.SimLaxConfig(ticks=10, train_interval=interval, ttl=ttl))
    assert sim.delivery_budget == bb.delivery
    assert sim.compact_budget == bb.compaction


def test_batched_overflow_names_offending_federation():
    """A compact_budget override too small for ONE member fails fast with
    that member's index in the error (not a silent receipt drop)."""
    n = 10
    topo = T.full(n)
    sc = scenarios.toy_scenario(n, dim=4)
    specs = [
        # member 0: a single staggered broadcaster -> tiny per-tick load
        FederationSpec.build(n, dead=tuple(range(1, n))),
        # member 1: everyone broadcasts on the same tick -> n*(n-1) due
        FederationSpec.build(n, initial_countdown=[2] * n),
    ]
    cfg = simlax.SimLaxConfig(ticks=12, train_interval=(8, 8), ttl=1,
                              record_every=4, compact_budget=2)
    sim = simlax.LaxSimulator(sc, topo,
                              BatchedFederationSpec.build(specs), IMPL2, cfg)
    with pytest.raises(RuntimeError, match=r"compact delivery overflow"
                       r".*federation \[1\]"):
        sim.run()


def test_batched_hypothesis_matches_singles():
    """Property sweep: random role sheets + seeds, batched == singles
    bitwise on the compact engine."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    n, ticks = 10, 30
    topo = T.kregular(n, 2)
    sc = scenarios.toy_scenario(n, dim=4)
    names = st.sampled_from(
        ["gaussian", "signflip", "scaled", "freerider", "intermittent"])
    spec_st = st.builds(
        lambda mal, dead: FederationSpec.build(
            n, malicious=mal, dead=tuple(d for d in dead
                                         if d not in mal)),
        st.dictionaries(st.integers(0, n - 1), names, max_size=3),
        st.sets(st.integers(0, n - 1), max_size=2))

    @settings(max_examples=8, deadline=None)
    @given(st.lists(spec_st, min_size=2, max_size=3),
           st.lists(st.integers(0, 2 ** 16), min_size=3, max_size=3))
    def prop(specs, seeds):
        seeds = seeds[:len(specs)]
        res = simlax.LaxSimulator(
            sc, topo, BatchedFederationSpec.build(specs, seeds),
            IMPL2, _cfg(ticks)).run()
        for b, (spec, seed) in enumerate(zip(specs, seeds)):
            single = simlax.LaxSimulator(
                sc, topo, spec, IMPL2, _cfg(ticks, seed=seed)).run()
            _assert_result_equal(res[b], single, b, "compact")

    prop()


@pytest.mark.slow
def test_batched_lenet_smoke_matches_singles():
    """Real-model (LeNet) batched run == singles, bitwise on params."""
    import jax

    n, ticks = 4, 12
    topo = T.full(n)
    sc = scenarios.lenet_scenario(n, pool=64, eval_size=16, test_size=64,
                                  train_steps=1, batch=8)
    specs = [FederationSpec.build(n, malicious=(0,), attack="gaussian"),
             FederationSpec.build(n)]
    cfg = simlax.SimLaxConfig(ticks=ticks, train_interval=(4, 4), ttl=1,
                              record_every=4)
    res = simlax.LaxSimulator(
        sc, topo, BatchedFederationSpec.build(specs, [0, 1]),
        IMPL2, cfg).run()
    for b, (spec, seed) in enumerate(zip(specs, [0, 1])):
        single = simlax.LaxSimulator(
            sc, topo, spec, IMPL2,
            simlax.SimLaxConfig(ticks=ticks, train_interval=(4, 4), ttl=1,
                                record_every=4, seed=seed)).run()
        for a, c in zip(jax.tree.leaves(res[b].params),
                        jax.tree.leaves(single.params)):
            assert np.array_equal(a, c), f"lenet federation {b}"
        assert np.array_equal(res[b].acc_history, single.acc_history)
