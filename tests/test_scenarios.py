"""Direct coverage of the shared-scenario constructors (previously only
exercised indirectly through full parity runs): the Scenario protocol +
name registry, the generic heap binder, the Dirichlet data plumbing, and
the vmappable LeNet callbacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chain import attacks, scenarios, simlax
from repro.chain.attacks import FederationSpec
from repro.core import topology as T
from repro.core.reputation import IMPL2


def test_scenario_registry():
    assert scenarios.names() == ("lenet", "toy")
    assert scenarios.get("toy") is scenarios.toy_scenario
    assert scenarios.get("lenet") is scenarios.lenet_scenario
    sc = scenarios.get("toy")(4, dim=3)
    assert isinstance(sc, scenarios.ToyScenario) and sc.num_nodes == 4
    with pytest.raises(KeyError, match="unknown scenario"):
        scenarios.get("mnist-for-real")


def test_scenarios_satisfy_protocol():
    toy = scenarios.toy_scenario(3)
    lenet = scenarios.lenet_scenario(2, pool=8, eval_size=4, test_size=8)
    for sc in (toy, lenet):
        assert isinstance(sc, scenarios.Scenario)
        # one uniform signature set: train_fn(params, key, data)
        stacked = sc.init_params_stacked()
        p0 = jax.tree.map(lambda x: x[0], stacked)
        d = sc.train_data()
        d0 = None if d is None else jax.tree.map(lambda x: x[0], d)
        out = sc.train_fn(p0, jax.random.PRNGKey(0), d0)
        assert jax.tree.structure(out) == jax.tree.structure(p0)
    assert toy.train_data() is None


def test_generic_heap_binder_applies_spec_roles():
    n = 5
    sc = scenarios.toy_scenario(n)
    spec = FederationSpec.build(
        n, malicious={1: "signflip", 3: "gaussian"},
        initial_countdown=[2] * n)
    nodes = scenarios.make_heap_nodes(sc, rep_impl=IMPL2, ttl=2, spec=spec)
    assert [nd.malicious for nd in nodes] == [False, True, False, True, False]
    assert nodes[1].attack.name == "signflip"
    assert nodes[3].attack is attacks.get("gaussian")
    # spec must match the scenario size
    with pytest.raises(ValueError, match="nodes"):
        scenarios.make_heap_nodes(sc, rep_impl=IMPL2, ttl=2,
                                  spec=FederationSpec.honest(n + 1))


def test_make_heap_simulator_from_spec():
    n = 6
    sc = scenarios.toy_scenario(n, malicious=())
    spec = FederationSpec.build(
        n, malicious=(0,), attack="freerider", dead=(4,),
        stragglers={2: 3}, initial_countdown=[1 + i for i in range(n)])
    cfg = simlax.SimLaxConfig(ticks=30, train_interval=(5, 5), latency=1,
                              ttl=2, record_every=10, seed=0)
    sim = scenarios.make_heap_simulator(sc, T.full(n), spec, IMPL2, cfg)
    assert sim.cfg.latency == (1, 1) and sim.cfg.ticks == 30
    assert sim.next_train == {f"n{i}": 1 + i for i in range(n)}
    assert sim.straggler_factor == {"n2": 3}
    assert sim.dead == {"n4"}
    assert sim.nodes["n0"].attack.name == "freerider"
    sim.run()
    assert sim.stats["tx_sent"] > 0


def test_toy_heap_nodes_construction():
    n = 5
    sc = scenarios.toy_scenario(n, malicious=(2,), seed=1)
    nodes = sc.make_heap_nodes(rep_impl=IMPL2, ttl=2, seed=1)
    assert len(nodes) == n
    assert [nd.name for nd in nodes] == [f"n{i}" for i in range(n)]
    assert [nd.malicious for nd in nodes] == [False, False, True, False, False]
    assert all(nd.ttl == 2 and nd.rep_impl is IMPL2 for nd in nodes)
    # train_fn pulls toward the target -> eval (closeness) strictly improves
    nd = nodes[0]
    before = nd.eval_fn(nd.params)
    params2, metrics = nd.train_fn(nd.params, jax.random.PRNGKey(0))
    assert metrics == {}
    after = nd.eval_fn(params2)
    assert 0.0 <= before < after <= 1.0
    # heap test_fn agrees with the stacked jax test_fn on the same params
    heap_test = sc.heap_test_fn()
    stacked = sc.init_params_stacked()
    want = float(sc.test_fn(jax.tree.map(lambda x: x[0], stacked)))
    assert heap_test({"w": jnp.asarray(sc.init_w[0])}) == pytest.approx(
        want, abs=1e-6)


def _tiny_lenet(n=3, malicious=(1,)):
    return scenarios.lenet_scenario(
        n, alpha=0.5, malicious=malicious, seed=0, pool=24, eval_size=8,
        test_size=16, train_steps=1, batch=4, lr=0.1)


def test_lenet_scenario_shapes_and_partition():
    n = 4
    sc = scenarios.lenet_scenario(n, alpha=0.3, seed=2, pool=32,
                                  eval_size=8, test_size=16)
    assert sc.num_nodes == n
    assert sc.train_images.shape == (n, 32, 28, 28, 1)
    assert sc.eval_labels.shape == (n, 8)
    assert sc.test_images.shape == (16, 28, 28, 1)
    # Dirichlet rows are distributions, and alpha=0.3 is visibly non-IID
    np.testing.assert_allclose(sc.class_probs.sum(axis=1), 1.0, atol=1e-6)
    assert sc.class_probs.max() > 0.25
    # iid variant: uniform rows
    iid = scenarios.lenet_scenario(n, alpha=None, pool=8, eval_size=4,
                                   test_size=8)
    np.testing.assert_allclose(iid.class_probs, 0.1)
    # per-node pools follow their distribution: label histograms differ
    h0 = np.bincount(sc.train_labels[0], minlength=10)
    h1 = np.bincount(sc.train_labels[1], minlength=10)
    assert (h0 != h1).any()
    # stacked init: one LeNet per node, distinct
    params = sc.init_params_stacked()
    assert params["c1"]["w"].shape == (n, 5, 5, 1, 6)
    assert not np.allclose(np.asarray(params["f1"]["w"][0]),
                           np.asarray(params["f1"]["w"][1]))


def test_lenet_vmappable_callbacks():
    sc = _tiny_lenet()
    params = sc.init_params_stacked()
    data, ed = sc.train_data(), sc.eval_data()
    keys = jax.random.split(jax.random.PRNGKey(0), sc.num_nodes)
    trained = jax.vmap(sc.train_fn)(params, keys, data)
    changed = jax.tree.map(
        lambda a, b: not np.allclose(np.asarray(a), np.asarray(b)),
        params, trained)
    assert all(jax.tree.leaves(changed))
    accs = jax.vmap(sc.eval_fn)(params, ed)
    assert accs.shape == (sc.num_nodes,)
    assert ((accs >= 0) & (accs <= 1)).all()
    t = jax.vmap(sc.test_fn)(params)
    assert ((t >= 0) & (t <= 1)).all()


def test_lenet_heap_nodes_construction():
    sc = _tiny_lenet()
    nodes = sc.make_heap_nodes(rep_impl=IMPL2, ttl=1)
    assert len(nodes) == sc.num_nodes
    assert [nd.malicious for nd in nodes] == [False, True, False]
    nd = nodes[0]
    acc = nd.eval_fn(nd.params)
    assert isinstance(acc, float) and 0.0 <= acc <= 1.0
    params2, metrics = nd.train_fn(nd.params, jax.random.PRNGKey(1))
    assert metrics == {}
    assert not np.allclose(np.asarray(params2["out"]["w"]),
                           np.asarray(nd.params["out"]["w"]))
    ht = sc.heap_test_fn()
    v = ht(nd.params)
    assert isinstance(v, float) and 0.0 <= v <= 1.0
