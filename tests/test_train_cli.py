"""End-to-end launcher tests (subprocess): plain training + checkpoint
resume, and DFL federated training with a mid-run node failure."""
import json

PLAIN_RESUME = r"""
import json, tempfile, os
from repro.launch import train as t
d = tempfile.mkdtemp()
t.main(["--arch", "xlstm-125m", "--smoke", "--steps", "6", "--batch", "2",
        "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "3"])
from repro.train import checkpoint as ck
assert ck.verify_chain(d)
m = ck.latest_manifest(d)
assert m["step"] == 6, m["step"]
# resume and continue
t.main(["--arch", "xlstm-125m", "--smoke", "--steps", "8", "--batch", "2",
        "--seq", "32", "--ckpt-dir", d, "--resume"])
print(json.dumps({"ok": True}))
"""

DFL_FAILURE = r"""
import json
from repro.launch import train as t
t.main(["--arch", "xlstm-125m", "--smoke", "--dfl", "--fed", "4",
        "--rounds", "4", "--local-steps", "1", "--ttl", "1",
        "--batch", "2", "--seq", "32", "--fail-node", "1@2"])
print(json.dumps({"ok": True}))
"""


def test_plain_train_and_resume(subprocess_runner):
    res = subprocess_runner(PLAIN_RESUME)
    assert res.returncode == 0, res.stderr[-2000:]
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]
    assert "resumed from step 6" in res.stdout


def test_dfl_federation_with_failure(subprocess_runner):
    res = subprocess_runner(DFL_FAILURE, host_devices=4)
    assert res.returncode == 0, res.stderr[-2000:]
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]
    assert "ring renumbers 4 -> 3" in res.stdout
