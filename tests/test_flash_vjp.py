"""Custom-VJP flash attention (jnp) vs naive attention: fwd + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention


def naive(q, k, v, causal, window):
    B, S, KH, G, Dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * Dh ** -0.5
    qp, kp = jnp.arange(S), jnp.arange(Skv)
    ok = jnp.ones((S, Skv), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok, s, -2e38)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
@pytest.mark.parametrize("bq,bkv", [(64, 64), (32, 128)])
def test_flash_vjp_matches_naive(causal, window, bq, bkv):
    key = jax.random.PRNGKey(0)
    B, S, KH, G, Dh = 2, 128, 2, 2, 32
    q = jax.random.normal(key, (B, S, KH, G, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, Dh))

    def f(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, causal, window, 0, bq, bkv)))

    def g(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, causal, window)))

    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, causal, window, 0, bq, bkv)),
        np.asarray(naive(q, k, v, causal, window)), rtol=1e-4, atol=1e-5)
    d1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    d2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(d1, d2):
        # bwd matmuls run with bf16 probabilities (fp32 accumulation) —
        # production trade documented in flash.py; grads match to bf16 eps
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-2, atol=3e-2)


def test_flash_vjp_under_jit_and_scan():
    """flash inside jit+scan (the transformer's usage pattern)."""
    key = jax.random.PRNGKey(1)
    B, S, KH, G, Dh = 1, 64, 1, 2, 16
    q = jax.random.normal(key, (B, S, KH, G, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, Dh))

    @jax.jit
    def loss(q, k, v):
        def body(c, _):
            o = flash_attention(q, k, v, True, 0, 0, 32, 32)
            return c + jnp.sum(o * o), None
        out, _ = jax.lax.scan(body, 0.0, None, length=3)
        return out

    g = jax.grad(loss)(q, k, v)
    assert np.isfinite(np.asarray(g)).all()
