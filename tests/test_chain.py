"""Blockchain substrate invariants (paper §IV-B/§IV-C): signatures, digest
protection, Eq. (1), two-phase blocks, chain immutability."""
import pytest

from repro.chain import crypto
from repro.chain.ledger import Ledger
from repro.chain.types import (BlockConfirmation, NodeInformation,
                               Receipt, Transaction)


@pytest.fixture(scope="module")
def keys():
    return crypto.generate_keypair(bits=512), crypto.generate_keypair(bits=512)


def _tx(kp, ttl=3, now=0.0):
    info = NodeInformation.from_keypair(kp)
    return Transaction(generator=info, create_time=now, expire_time=now + 50,
                       ml_model="abc123", ttl=ttl).seal(kp)


def test_sign_verify_roundtrip(keys):
    kp, other = keys
    d = crypto.hash_fields("hello", 42)
    sig = crypto.sign(kp, d)
    assert crypto.verify(kp.public_key, d, sig)
    assert not crypto.verify(other.public_key, d, sig)
    assert not crypto.verify(kp.public_key, crypto.hash_fields("x"), sig)


def test_address_is_pubkey_hash(keys):
    kp, _ = keys
    assert kp.address == crypto.sha256_hex(kp.public_key.encode())


def test_transaction_tamper_detection(keys):
    kp, _ = keys
    tx = _tx(kp)
    assert tx.verify()
    tx.ml_model = "evil"
    assert not tx.verify()


def test_transaction_expiry(keys):
    kp, _ = keys
    tx = _tx(kp, now=0.0)
    assert tx.verify(now=10.0)
    assert not tx.verify(now=51.0)  # outdated model dropped (§IV-B2)


def test_receipt_digest_not_part_of_tx_digest(keys):
    """§IV-B3: appending receipts must not change the transaction digest."""
    kp, kp2 = keys
    tx = _tx(kp)
    d_before = tx.d
    r = Receipt(creator=NodeInformation.from_keypair(kp2),
                transaction_digest=tx.d, received_at_ttl=tx.ttl - 1,
                accuracy=0.9, create_time=1.0).seal(kp2)
    tx.receipts.append(r)
    assert tx.compute_digest() == d_before
    assert tx.verify()


def test_received_at_ttl_eq1(keys):
    """Eq. (1): received_at_ttl = min(ttl, min receipts.rat) - 1."""
    kp, kp2 = keys
    tx = _tx(kp, ttl=3)
    assert tx.next_received_at_ttl() == 2
    r = Receipt(creator=NodeInformation.from_keypair(kp2),
                transaction_digest=tx.d, received_at_ttl=1,
                accuracy=0.5, create_time=1.0).seal(kp2)
    tx.receipts.append(r)
    assert tx.next_received_at_ttl() == 0  # min(3, 1) - 1


def test_block_two_phase_and_confirmations(keys):
    kp, kp2 = keys
    info2 = NodeInformation.from_keypair(kp2)
    ledger = Ledger("lenet5", NodeInformation.from_keypair(kp), kp)
    tx = _tx(kp)
    r = Receipt(creator=info2, transaction_digest=tx.d,
                received_at_ttl=2, accuracy=0.8, create_time=1.0).seal(kp2)
    tx.receipts.append(r)
    draft = ledger.new_draft([tx], now=2.0)
    conf = BlockConfirmation(creator=info2, transaction_digest=tx.d,
                             receipt_digest=r.d, block_digest=draft.d).seal(kp2)
    draft.confirmations = [conf]
    draft.finalize()
    assert draft.verify(min_confirmations_per_tx=1)
    assert ledger.append(draft, 1)
    assert ledger.verify_chain(1)


def test_block_immutable_after_finalize(keys):
    kp, kp2 = keys
    info2 = NodeInformation.from_keypair(kp2)
    ledger = Ledger("lenet5", NodeInformation.from_keypair(kp), kp)
    tx = _tx(kp)
    r = Receipt(creator=info2, transaction_digest=tx.d, received_at_ttl=2,
                accuracy=0.8, create_time=1.0).seal(kp2)
    tx.receipts.append(r)
    draft = ledger.new_draft([tx], now=2.0)
    conf = BlockConfirmation(creator=info2, transaction_digest=tx.d,
                             receipt_digest=r.d, block_digest=draft.d).seal(kp2)
    draft.confirmations = [conf]
    draft.finalize()
    ledger.append(draft, 1)
    # tampering with a sealed receipt breaks the chain audit
    r.accuracy = 1.0
    assert not ledger.verify_chain(1)


def test_confirmation_for_foreign_receipt_rejected(keys):
    kp, kp2 = keys
    info2 = NodeInformation.from_keypair(kp2)
    ledger = Ledger("lenet5", NodeInformation.from_keypair(kp), kp)
    tx = _tx(kp)
    draft = ledger.new_draft([tx], now=2.0)
    bogus = BlockConfirmation(creator=info2, transaction_digest=tx.d,
                              receipt_digest="f" * 64,
                              block_digest=draft.d).seal(kp2)
    draft.confirmations = [bogus]
    draft.finalize()
    assert not draft.verify(min_confirmations_per_tx=0)


def test_genesis_records_model_structure(keys):
    kp, _ = keys
    a = Ledger("lenet5", NodeInformation.from_keypair(kp), kp)
    b = Ledger("resnet", NodeInformation.from_keypair(kp), kp)
    assert a.genesis_digest != b.genesis_digest  # §IV-B4
